"""Hybrid adaptive packet/flow backend: bit-exact packet fidelity, the
acceptance event-cut/accuracy bounds on the paper workloads, granularity
transitions (demote/promote/re-solve), and the PartitionIndex granularity
tags the lane machinery keys off."""
import pytest

from repro.api import (FlowSpec, Scenario, TopologySpec, run, run_many,
                      training_scenario)
from repro.api.analytic import maxmin_rates
from repro.core.partition import PartitionIndex
from repro.net.hybrid_sim import HybridConfig


def wave_scenario(second_wave: float = 0.02, name: str = "hwaves") -> Scenario:
    """The quickstart contention pattern; ``second_wave`` inside the first
    wave's lifetime (~1.5 ms) turns the second launch into a promotion
    interrupt for the demoted first-wave partitions."""
    flows = []
    fid = 0
    for wave in (0.0, second_wave):
        for i in range(4):
            flows.append(FlowSpec(fid, i, 12 + (i % 2), size=8e6,
                                  start=wave, cca="dctcp", tag=f"w{wave:g}"))
            fid += 1
    return Scenario(name, TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                                "n_spines": 2}), flows=flows)


# --------------------------------------------------------------------- #
# fidelity="packet": bit-identical to the sharded serial loop
# --------------------------------------------------------------------- #
def test_fidelity_packet_bit_identical_to_sharded_serial():
    scn = wave_scenario()
    sharded = run(scn, backend="packet", parallel="partitions")
    hyb = run(scn, backend="hybrid", fidelity="packet")
    assert hyb.fcts == sharded.fcts
    assert hyb.events_processed == sharded.events_processed
    # ... and the sharded serial loop is itself identical to the single-heap
    # serial loop, so transitively to the packet oracle
    serial = run(scn, backend="packet")
    assert hyb.fcts == serial.fcts
    assert hyb.events_processed == serial.events_processed
    g = hyb.extras["granularity"]
    assert g["demotions"] == 0 and g["flow_lane_events"] == 0
    assert g["packet_lane_events"] > 0


# --------------------------------------------------------------------- #
# acceptance: >=3x fewer packet-lane events, <1% mean FCT error
# --------------------------------------------------------------------- #
def _assert_acceptance(scn, min_cut=3.0, max_err=0.01):
    base = run(scn, backend="packet")
    auto = run(scn, backend="hybrid", fidelity="auto")
    g = auto.extras["granularity"]
    cut = base.events_processed / max(g["packet_lane_events"], 1)
    err = float(auto.fct_errors_vs(base).mean())
    assert cut >= min_cut, f"packet-lane cut {cut:.2f}x < {min_cut}x"
    assert err < max_err, f"mean FCT err {err:.4f} >= {max_err}"
    assert g["demotions"] > 0
    assert set(auto.fcts) == set(base.fcts)
    return auto


def test_acceptance_quickstart():
    _assert_acceptance(wave_scenario())


@pytest.mark.slow
def test_acceptance_64gpu_preset():
    _assert_acceptance(training_scenario(n_gpus=64, cca="hpcc", scale=1 / 256))


@pytest.mark.slow
def test_acceptance_moe_ep_preset():
    # the paper's hardest workload: EP all-to-all domains carved from DP
    # (1/512 scale keeps the packet oracle affordable in CI; the full
    # 1/256 row runs in benchmarks/paper_figures.hybrid_tradeoff)
    scn = training_scenario(n_gpus=64, moe=True, cca="hpcc", scale=1 / 512)
    assert scn.workload.family == "moe"
    _assert_acceptance(scn)


# --------------------------------------------------------------------- #
# granularity transitions
# --------------------------------------------------------------------- #
def test_promotion_on_flow_entry():
    """A second wave landing mid-demotion must promote the affected flow
    lanes back to packet granularity (contention-pattern change) and stay
    bounded in error — this is unsteady traffic neither pure backend
    handles at this cost."""
    scn = wave_scenario(second_wave=0.0008, name="overlap")
    base = run(scn, backend="packet")
    auto = run(scn, backend="hybrid")
    g = auto.extras["granularity"]
    assert g["promotions"] > 0, "flow entry must promote demoted partitions"
    assert g["demotions"] > g["promotions"], "partitions re-demote after"
    assert float(auto.fct_errors_vs(base).mean()) < 0.10  # bounded, coarser
    assert g["packet_lane_events"] < base.events_processed


def test_completion_resolve_keeps_flow_lane():
    """Unequal flows in one partition: the first virtual completion re-solves
    the survivors' shares and keeps them in the flow lane (no promotion)."""
    flows = [FlowSpec(0, 0, 12, 8e6, 0.0, "dctcp"),
             FlowSpec(1, 1, 12, 12e6, 0.0, "dctcp")]
    scn = Scenario("uneven", TopologySpec("clos", {"n_hosts": 16,
                   "leaf_down": 4, "n_spines": 2}), flows=flows)
    base = run(scn, backend="packet")
    auto = run(scn, backend="hybrid")
    g = auto.extras["granularity"]
    assert g["resolves"] >= 1, "survivor must re-enter the flow lane"
    assert float(auto.fct_errors_vs(base).mean()) < 0.02


def test_fidelity_flow_is_coarse_and_cheap():
    scn = wave_scenario()
    base = run(scn, backend="packet")
    fl = run(scn, backend="hybrid", fidelity="flow")
    g = fl.extras["granularity"]
    assert g["packet_lane_events"] == 0
    assert fl.events_processed < base.events_processed / 100
    assert set(fl.fcts) == set(base.fcts)
    # flow-level abstraction error, not packet accuracy
    assert float(fl.fct_errors_vs(base).mean()) < 0.35


def test_validate_mode_checks_invariants():
    scn = wave_scenario(second_wave=0.0008, name="overlap-v")
    plain = run(scn, backend="hybrid")
    checked = run(scn, backend="hybrid", validate=True)
    assert checked.fcts == plain.fcts


@pytest.mark.slow
def test_intra_workers_parity():
    """The hybrid backend rides the sharded loop's fan-out machinery:
    results are identical for any worker count."""
    scn = wave_scenario(second_wave=0.0008, name="overlap-iw")
    serial = run(scn, backend="hybrid")
    par = run(scn, backend="hybrid", intra_workers=2)
    assert par.fcts == serial.fcts
    assert par.events_processed == serial.events_processed
    assert (par.extras["granularity"]["packet_lane_events"]
            == serial.extras["granularity"]["packet_lane_events"])


# --------------------------------------------------------------------- #
# knobs + registry seams
# --------------------------------------------------------------------- #
def test_unknown_fidelity_raises():
    with pytest.raises(ValueError, match="fidelity"):
        run(wave_scenario(), backend="hybrid", fidelity="quantum")


def test_config_ignores_foreign_kernel_knobs():
    # scenarios share one kernel dict across backends: wormhole's theta
    # must not break the hybrid engine
    cfg = HybridConfig.from_knobs({"theta": 0.05, "demote_after": 4})
    assert cfg.demote_after == 4


def test_config_fidelity_respected_and_not_mutated():
    """An unset engine opt must not clobber a fidelity carried by config=,
    and the caller's HybridConfig must come back untouched."""
    scn = wave_scenario()
    cfg = HybridConfig(fidelity="flow")
    r = run(scn, backend="hybrid", config=cfg)
    assert r.extras["granularity"]["packet_lane_events"] == 0
    assert cfg.fidelity == "flow"
    run(scn, backend="hybrid", config=cfg, fidelity="auto",
        demote_after=4)                       # explicit opts win ...
    assert cfg.fidelity == "flow"             # ... without mutating cfg
    assert cfg.demote_after == HybridConfig().demote_after


def test_flow_fidelity_survives_max_demote_horizon():
    """In "flow" mode there is no detector to hand a partition back to, so
    the max_demote probe must not strand it at packet granularity — the
    lane runs to its virtual completions even when they lie far beyond
    max_demote."""
    scn = wave_scenario()
    r = run(scn, backend="hybrid", fidelity="flow",
            config={"max_demote": 1e-4})      # << the ~1.5 ms flow lifetime
    g = r.extras["granularity"]
    assert g["packet_lane_events"] == 0
    assert g["probes"] == 0 and g["promotions"] == 0


def test_demote_after_knob_threads_through():
    scn = wave_scenario()
    eager = run(scn, backend="hybrid", demote_after=4)
    lazy = run(scn, backend="hybrid", demote_after=24)
    ge, gl = eager.extras["granularity"], lazy.extras["granularity"]
    assert ge["packet_lane_events"] < gl["packet_lane_events"], \
        "a longer demotion window must keep more packet-lane events"


def test_run_many_rejects_db_for_hybrid():
    with pytest.raises(ValueError, match="wormhole"):
        run_many([wave_scenario()], backend="hybrid", shared_db=True)


# --------------------------------------------------------------------- #
# PartitionIndex granularity tags + the factored max-min solver
# --------------------------------------------------------------------- #
def test_partition_granularity_tags():
    idx = PartitionIndex()
    pid_a, _ = idx.add_flow(1, frozenset({10, 11}))
    assert idx.granularity[pid_a] == "packet"
    idx.set_granularity(pid_a, "flow")
    # a merge is a new contention pattern: tag resets to packet
    pid_b, merged = idx.add_flow(2, frozenset({11, 12}))
    assert merged == {pid_a}
    assert idx.granularity[pid_b] == "packet"
    idx.set_granularity(pid_b, "flow")
    idx.add_flow(3, frozenset({12, 13}))
    pid_c = idx.flow_pid[1]
    idx.set_granularity(pid_c, "flow")
    # a split inherits the parent's granularity (contention only shrank)
    idx.remove_flow(2)
    assert all(idx.granularity[idx.flow_pid[f]] == "flow" for f in (1, 3))
    idx.check_invariants()
    with pytest.raises(ValueError):
        idx.set_granularity(idx.flow_pid[1], "plasma")
    with pytest.raises(KeyError):
        idx.set_granularity(999, "flow")


def test_maxmin_rates_water_filling():
    # two flows share link 0 (cap 10); flow 2 alone on link 1 (cap 4)
    rates = maxmin_rates({1: [0], 2: [0, 1], 3: [0]},
                         {0: 10.0, 1: 4.0})
    assert rates[2] == pytest.approx(10 / 3)       # link 0 binds first
    assert rates[1] == rates[3] == pytest.approx(10 / 3)
    rates = maxmin_rates({1: [0], 2: [1]}, {0: 10.0, 1: 4.0})
    assert rates[1] == pytest.approx(10.0)
    assert rates[2] == pytest.approx(4.0)
