"""Event-loop correctness regressions: run(until=) tie determinism and the
timeout safety net's superseded-event voiding (the two single-heap bugs
fixed alongside the partition-sharded scheduler)."""
import pytest

from repro.net.flows import FlowSpec
from repro.net.packet_sim import PacketSim
from repro.net.topology import leaf_spine_clos


def _sim(**kw):
    return PacketSim(leaf_spine_clos(16, leaf_down=4, n_spines=2), **kw)


# --------------------------------------------------------------------- #
# run(until=...) must preserve same-timestamp tie order across a resume
# --------------------------------------------------------------------- #
def test_until_preserves_same_timestamp_tie_order():
    """Regression: the peeked-past-deadline event used to be re-pushed with
    a *fresh* seq, so it lost its tie-break position against a later-
    scheduled event at the same timestamp and the resume reordered them."""
    sim = _sim()
    log = []
    sim.call_at(5e-3, lambda now: log.append("first"))
    sim.call_at(5e-3, lambda now: log.append("second"))
    sim.run(until=1e-3)           # deadline peeks at the first CALL
    assert log == []
    sim.run()
    assert log == ["first", "second"], \
        "resume must execute same-t events in scheduling order"


def test_until_resume_matches_uninterrupted_run():
    """run(until=t); run() must be event-for-event identical to run()."""
    def scenario(sim):
        for i in range(6):
            sim.add_flow(FlowSpec(i, i, 8 + i % 2, 4e5, (i % 3) * 1e-4,
                                  "dctcp"))
        return sim

    one = scenario(_sim())
    one.record_rtt_fids = {0, 3}
    one.run()

    two = scenario(_sim())
    two.record_rtt_fids = {0, 3}
    # interrupt mid-flight several times, then run to completion
    for until in (2e-4, 5e-4, 9e-4):
        two.run(until=until)
    two.run()

    assert one.all_done() and two.all_done()
    assert {f: r.fct for f, r in one.results.items()} == \
           {f: r.fct for f, r in two.results.items()}
    assert one.events_processed == two.events_processed
    for fid in (0, 3):
        assert one.flows[fid].rtt_samples == two.flows[fid].rtt_samples


# --------------------------------------------------------------------- #
# timeout safety net: superseded in-flight events must die, not deliver
# --------------------------------------------------------------------- #
def _timeout_run(force: bool):
    """One flow on a slow bottleneck; optionally force a (spurious) timeout
    one third of the way through by faking a stalled last_ack_t."""
    topo = leaf_spine_clos(4, leaf_down=4, n_spines=1, bw=1e8)
    sim = PacketSim(topo, sample_interval=2e-5, ecn_k=1e12)
    sim.add_flow(FlowSpec(0, 0, 1, 3e5, 0.0, "dctcp"))
    if force:
        sim.run(until=1e-3)                 # mid-transfer, window in flight
        f = sim.flows[0]
        assert not f.done and f.inflight > 0
        f.last_ack_t = -1.0                 # next sample trips the net
    sim.run()
    assert sim.all_done()
    return sim


def test_timeout_voids_superseded_inflight_events():
    """Regression: the net moved ``inflight`` into ``retx`` but left the
    original ARRIVE/ACK/LOSS events live (same epoch).  When a late ACK
    landed, ``delivered`` counted bytes that were *also* queued for
    retransmission, finishing the flow early — i.e. a spurious timeout used
    to make the flow *faster* than the undisturbed run."""
    base = _timeout_run(force=False)
    assert base.timeouts == 0
    hit = _timeout_run(force=True)
    assert hit.timeouts >= 1, "scenario must trip the safety net"
    f = hit.flows[0]
    assert f.delivered == pytest.approx(f.spec.size)
    # every voided byte has to cross the bottleneck again: the disturbed
    # run is strictly slower, never faster
    assert hit.results[0].fct > base.results[0].fct


def test_timeout_trips_organically_with_deep_buffers():
    """A latecomer's packets stuck behind a deep shared backlog see their
    first ACK beyond the net's threshold: the timeout must fire and the
    flow must still deliver every byte exactly once (no early finish)."""
    topo = leaf_spine_clos(16, leaf_down=16, n_spines=1, bw=1e8)
    sim = PacketSim(topo, sample_interval=1e-5, ecn_k=1e12,
                    buffer_bytes=1e8)
    for i in range(1, 16):                   # blasters build the backlog
        sim.add_flow(FlowSpec(i, i, 0, 2e6, 0.0, "dctcp"))
    sim.add_flow(FlowSpec(99, 1, 0, 2e3, 4e-3, "dctcp"))   # the latecomer
    sim.run()
    assert sim.all_done()
    assert sim.timeouts >= 1, "deep backlog must trip the safety net"
    late = sim.flows[99]
    assert late.delivered == pytest.approx(late.spec.size)
    # byte conservation: the bytes crossed the bottleneck at least once
    assert sim.results[99].fct * 1e8 >= late.spec.size
