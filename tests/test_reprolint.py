"""reprolint framework tests.

Every shipped rule must fire on its seeded violation in
``tests/lint_fixtures/`` at the exact (rule, file, line); pragmas suppress;
the baseline round-trips; and the schema-fingerprint ``--update`` is
additions-aware — it records new schemas but REFUSES a field change that
was not paired with a version bump (demonstrated against a temp-tree copy
of the real sources, per the acceptance criteria).
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import shutil
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

from reprolint import (
    Config,
    SchemaSpec,
    all_rules,
    apply_baseline,
    iter_py_files,
    load_baseline,
    run_lint,
    write_baseline,
)
from reprolint import cli, rules_contracts
from reprolint.config import DETERMINISM_SCOPE
from reprolint.engine import Finding, in_scope, pragma_lines

FIX = "tests/lint_fixtures"


def fixture_config(root: pathlib.Path = REPO, **over) -> Config:
    """A Config whose registries point at the fixture corpus instead of the
    real tree (the corpus deliberately violates every rule)."""
    base = Config(
        root=root,
        excludes=(),
        baseline_path=f"{FIX}/nonexistent_baseline.json",
        fingerprint_path=f"{FIX}/c_schema_fingerprint.json",
        hot_classes=((f"{FIX}/h_slots.py", "FixtureHot"),),
        schemas=(SchemaSpec("FixtureRecord", "dataclass",
                            f"{FIX}/c_schema.py", "FixtureRecord",
                            f"{FIX}/c_schema.py", "SCHEMA_VERSION"),),
        worker_entries=("s_worker_entry",),
        module_roots=(FIX,),
    )
    return dataclasses.replace(base, **over) if over else base


def lint_fixtures(config: Config | None = None, paths=(FIX,)):
    config = config or fixture_config()
    files = iter_py_files(list(paths), config.root, config.excludes)
    return run_lint(files, config)


# ------------------------------------------------------------------ #
# every rule fires, at the exact location
# ------------------------------------------------------------------ #
EXPECTED = {
    ("D101", f"{FIX}/d_rules.py", 9),
    ("D102", f"{FIX}/d_rules.py", 13),
    ("D103", f"{FIX}/d_rules.py", 17),
    ("D104", f"{FIX}/d_rules.py", 22),
    ("H201", f"{FIX}/h_rules.py", 11),
    ("H202", f"{FIX}/h_rules.py", 16),
    ("H203", f"{FIX}/h_rules.py", 22),
    ("H204", f"{FIX}/h_rules.py", 28),
    ("H205", f"{FIX}/h_slots.py", 17),
    ("C301", f"{FIX}/c_engines.py", 8),
    ("C302", f"{FIX}/c_engines.py", 15),
    ("C303", f"{FIX}/c_schema_fingerprint.json", 1),
    ("C304", f"{FIX}/c_schema_fingerprint.json", 1),
    ("S401", f"{FIX}/s_submit.py", 7),
    ("S401", f"{FIX}/s_submit.py", 12),
    ("S402", f"{FIX}/s_jaxy.py", 2),
}


def test_every_rule_fires_at_exact_location():
    _tree, findings, _sup = lint_fixtures()
    got = {(f.rule, f.path, f.line) for f in findings}
    missing = EXPECTED - got
    assert not missing, f"rules did not fire as seeded: {sorted(missing)}"
    # the corpus seeds one violation per rule — nothing else may fire
    unexpected = {g for g in got
                  if g not in EXPECTED
                  and g != ("D103", f"{FIX}/d_rules.py", 17)}  # fires twice
    assert not unexpected, f"unexpected findings: {sorted(unexpected)}"


def test_all_registered_rules_are_covered():
    fired = {f.rule for f in lint_fixtures()[1]}
    registered = {info.rule_id for info in all_rules()}
    assert registered <= fired, (
        f"rules with no firing fixture: {sorted(registered - fired)}")
    assert len(registered) >= 10


# ------------------------------------------------------------------ #
# pragmas
# ------------------------------------------------------------------ #
def test_pragma_suppression():
    config = fixture_config()
    files = iter_py_files([f"{FIX}/pragma_ok.py"], REPO, ())
    _tree, findings, suppressed = run_lint(files, config)
    per_file = [f for f in findings if f.path.endswith("pragma_ok.py")]
    assert per_file == []
    assert suppressed == 3   # inline D101, comment-line D104, wildcard D102


def test_pragma_parsing_shapes():
    src = ("x = 1  # reprolint: allow[D101, H201]\n"
           "# reprolint: allow[*]\n"
           "y = 2\n")
    allowed = pragma_lines(src)
    assert allowed[1] == {"D101", "H201"}
    assert allowed[2] == {"*"}
    assert allowed[3] == {"*"}   # comment-only pragma covers the next line


# ------------------------------------------------------------------ #
# scoping
# ------------------------------------------------------------------ #
def test_determinism_scope():
    assert in_scope("src/repro/core/fcg.py", DETERMINISM_SCOPE)
    assert in_scope("src/repro/net/packet_sim.py", DETERMINISM_SCOPE)
    assert not in_scope("src/repro/learned/fit.py", DETERMINISM_SCOPE)
    assert not in_scope("benchmarks/ci_regression.py", DETERMINISM_SCOPE)
    # the fixture corpus is always in scope — rules must be provable
    assert in_scope(f"{FIX}/d_rules.py", DETERMINISM_SCOPE)


# ------------------------------------------------------------------ #
# baseline
# ------------------------------------------------------------------ #
def test_baseline_roundtrip(tmp_path):
    _tree, findings, _sup = lint_fixtures()
    bl_path = tmp_path / "baseline.json"
    write_baseline(bl_path, findings)
    baseline = load_baseline(bl_path)
    new, grandfathered, stale = apply_baseline(findings, baseline)
    assert new == [] and stale == []
    assert len(grandfathered) == len(findings)

    # a fixed finding leaves its baseline entry stale -> must be reported
    fixed, rest = findings[0], findings[1:]
    new, _g, stale = apply_baseline(rest, baseline)
    assert new == []
    assert stale == [fixed.key()]

    # a brand-new finding is not grandfathered
    extra = Finding("src/new.py", 3, 1, "D101", "msg")
    new, _g, stale2 = apply_baseline(list(findings) + [extra], baseline)
    assert new == [extra] and stale2 == []


def test_baseline_keys_are_line_free():
    f1 = Finding("a.py", 10, 1, "D101", "msg")
    f2 = Finding("a.py", 99, 5, "D101", "msg")
    assert f1.key() == f2.key()   # line churn keeps grandfathering


# ------------------------------------------------------------------ #
# schema fingerprint: version-bump enforcement + additions-aware --update
# ------------------------------------------------------------------ #
def _copy_fixtures(tmp_path) -> pathlib.Path:
    root = tmp_path / "tree"
    shutil.copytree(REPO / FIX, root / FIX)
    return root


def test_update_refuses_versionless_field_change():
    # the committed fixture IS the violation: FixtureRecord grew a field,
    # SCHEMA_VERSION stayed 1.  --update must refuse (and not write).
    config = fixture_config()
    before = (REPO / config.fingerprint_path).read_text()
    ok, messages = rules_contracts.update_fingerprint(config)
    assert ok is False
    assert any("refusing" in m and "version" in m for m in messages)
    assert (REPO / config.fingerprint_path).read_text() == before


def test_update_accepts_change_with_version_bump(tmp_path):
    root = _copy_fixtures(tmp_path)
    schema_py = root / FIX / "c_schema.py"
    schema_py.write_text(
        schema_py.read_text().replace("SCHEMA_VERSION = 1",
                                      "SCHEMA_VERSION = 2"))
    config = fixture_config(root=root)
    ok, _messages = rules_contracts.update_fingerprint(config)
    assert ok is True
    fp = json.loads((root / config.fingerprint_path).read_text())
    assert fp["schemas"]["FixtureRecord"]["version"] == 2
    assert "added_without_bump" in fp["schemas"]["FixtureRecord"]["fields"]
    # hot-slots drift was re-recorded too; the tree now lints C303/C304-clean
    _tree, findings, _sup = lint_fixtures(
        dataclasses.replace(config, worker_entries=()),
        paths=(str(root / FIX),))
    assert not [f for f in findings if f.rule in ("C303", "C304")]


def test_update_is_additions_aware(tmp_path):
    # a schema NEW to the config is a drift (not a refusal): --update
    # records it and keeps the existing entries intact
    root = _copy_fixtures(tmp_path)
    schema_py = root / FIX / "c_schema.py"
    schema_py.write_text(
        schema_py.read_text().replace("SCHEMA_VERSION = 1",
                                      "SCHEMA_VERSION = 2")
        + "\n\n@dataclasses.dataclass\nclass SecondRecord:\n    a: int\n")
    config = fixture_config(root=root)
    config = dataclasses.replace(config, schemas=config.schemas + (
        SchemaSpec("SecondRecord", "dataclass", f"{FIX}/c_schema.py",
                   "SecondRecord", f"{FIX}/c_schema.py", "SCHEMA_VERSION"),))
    ok, _messages = rules_contracts.update_fingerprint(config)
    assert ok is True
    fp = json.loads((root / config.fingerprint_path).read_text())
    assert set(fp["schemas"]) == {"FixtureRecord", "SecondRecord"}
    assert fp["schemas"]["SecondRecord"]["fields"] == ["a"]


# ------------------------------------------------------------------ #
# acceptance: real-tree mutations fail the gate (temp-tree copy)
# ------------------------------------------------------------------ #
REAL_FILES = (
    "src/repro/core/memo.py",
    "src/repro/api/results.py",
    "src/repro/api/store.py",
    "src/repro/learned/fit.py",
    "src/repro/learned/model.py",
    "src/repro/net/packet_sim.py",
    "src/repro/net/sharded_sim.py",
    "src/repro/net/hybrid_sim.py",
    "src/repro/net/soa.py",
    "src/repro/net/cca.py",
    "src/repro/core/wormhole.py",
    "artifacts/schema_fingerprint.json",
)


def _copy_real_tree(tmp_path) -> tuple[pathlib.Path, Config]:
    root = tmp_path / "repo"
    for rel in REAL_FILES:
        dst = root / rel
        dst.parent.mkdir(parents=True, exist_ok=True)
        shutil.copy(REPO / rel, dst)
    return root, Config(root=root)


def _contract_findings(config: Config) -> list:
    _tree, findings, _sup = run_lint([], config)   # tree rules only
    return [f for f in findings if f.rule in ("C303", "C304")]


def test_real_tree_is_fingerprint_clean(tmp_path):
    _root, config = _copy_real_tree(tmp_path)
    assert _contract_findings(config) == []


def test_versionless_dataclass_field_change_fails(tmp_path):
    root, config = _copy_real_tree(tmp_path)
    memo = root / "src/repro/core/memo.py"
    memo.write_text(memo.read_text().replace(
        "    hits: int = 0", "    hits: int = 0\n    surprise: int = 0"))
    findings = _contract_findings(config)
    assert any(f.rule == "C303" and "MemoEntry" in f.message
               and "version" in f.message for f in findings)
    ok, messages = rules_contracts.update_fingerprint(config)
    assert ok is False and any("refusing" in m for m in messages)
    # the same change WITH a bump is accepted by --update
    memo.write_text(memo.read_text().replace("FORMAT_VERSION = 1",
                                             "FORMAT_VERSION = 2"))
    ok, _messages = rules_contracts.update_fingerprint(config)
    assert ok is True
    assert _contract_findings(config) == []


def test_hot_class_slots_change_fails(tmp_path):
    root, config = _copy_real_tree(tmp_path)
    ps = root / "src/repro/net/packet_sim.py"
    src = ps.read_text()
    assert '"timeouts", ' in src
    ps.write_text(src.replace('"timeouts", ', "", 1))
    findings = _contract_findings(config)
    assert any(f.rule == "C304" and "PacketSim" in f.message
               for f in findings)


# ------------------------------------------------------------------ #
# CLI: the real tree passes the exact CI gate
# ------------------------------------------------------------------ #
def _run_cli(*args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "tools")
    return subprocess.run(
        [sys.executable, "-m", "reprolint", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)


def test_cli_real_tree_clean():
    proc = _run_cli("src", "tests")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stderr


def test_cli_list_rules():
    proc = _run_cli("--list-rules")
    assert proc.returncode == 0
    for rule_id in ("D101", "H205", "C303", "S402"):
        assert rule_id in proc.stdout


def test_cli_github_format_emits_annotations(tmp_path):
    # scan one fixture file through the CLI with --root pointed at a temp
    # tree so the default excludes don't drop it
    root = _copy_fixtures(tmp_path)
    (root / "pyproject.toml").write_text("")   # root marker for the CLI
    src_dir = root / "src" / "repro" / "core"  # inside the D-rule scope
    src_dir.mkdir(parents=True)
    shutil.copy(REPO / FIX / "d_rules.py", src_dir / "d_rules.py")
    proc = _run_cli("src", "--root", str(root))
    assert proc.returncode == 1
    proc = _run_cli("src", "--root", str(root), "--format", "github")
    assert proc.returncode == 1
    assert "::error file=src/repro/core/d_rules.py" in proc.stdout
    assert "title=reprolint D101" in proc.stdout
