"""End-to-end Wormhole kernel vs the packet-level oracle (paper §7 claims)."""
import pytest

from repro.core.memo import STEADY, MemoEntry, MemoHit, SimDB
from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net.flows import FlowSpec
from repro.net.packet_sim import PacketSim
from repro.net.topology import leaf_spine_clos, rail_optimized_fat_tree


def ring_workload(kernel=None, cca="dctcp", size=6e6, waves=2):
    topo = rail_optimized_fat_tree(8, gpus_per_server=4, leaf_radix=8, n_spines=2)
    sim = PacketSim(topo, kernel=kernel)
    fid = 0
    for w in range(waves):
        for r in range(4):
            for s in range(8):
                src = s * 4 + r
                dst = ((s + 1) % 8) * 4 + r
                sim.add_flow(FlowSpec(fid, src, dst, size, w * 0.02, cca, tag=f"ring{w}"))
                fid += 1
    sim.run()
    assert sim.all_done()
    return sim


def fct_errors(base, wh):
    assert set(base.results) == set(wh.results), "user-transparency: same flows"
    return {fid: abs(wh.results[fid].fct - r.fct) / r.fct for fid, r in base.results.items()}


@pytest.fixture(scope="module")
def baseline():
    return ring_workload()


def test_fct_error_below_one_percent(baseline):
    k = WormholeKernel(WormholeConfig())
    wh = ring_workload(k)
    errs = fct_errors(baseline, wh)
    assert sum(errs.values()) / len(errs) < 0.01, "paper claim: <1% mean FCT error"
    assert max(errs.values()) < 0.05


def test_event_speedup_and_skip_ratio(baseline):
    k = WormholeKernel(WormholeConfig())
    wh = ring_workload(k)
    assert baseline.events_processed / wh.events_processed > 2.0
    rep = k.report()
    skip = rep["est_events_skipped"] / (rep["est_events_skipped"] + wh.events_processed)
    assert skip > 0.5


def test_memoization_hits_on_repeated_waves(baseline):
    k = WormholeKernel(WormholeConfig())
    ring_workload(k)
    assert k.db.hits >= 16, "wave 2 must reuse wave 1's transients"
    # and memoization must not change results beyond steady-skip error
    k2 = WormholeKernel(WormholeConfig(enable_memo=False))
    wh2 = ring_workload(k2)
    errs = fct_errors(baseline, wh2)
    assert sum(errs.values()) / len(errs) < 0.01


def test_steady_only_and_memo_only_modes(baseline):
    for cfg in (WormholeConfig(enable_memo=False),
                WormholeConfig(enable_steady=False)):
        k = WormholeKernel(cfg)
        wh = ring_workload(k)
        errs = fct_errors(baseline, wh)
        assert sum(errs.values()) / len(errs) < 0.02


def test_conservation_under_wormhole():
    k = WormholeKernel(WormholeConfig())
    wh = ring_workload(k)
    for f in wh.flows.values():
        assert f.done
        assert abs(f.delivered - f.spec.size) < 1.0


def test_skip_back_with_realtime_arrivals():
    """Flows arriving mid-steady-period must trigger skip-back, and results
    stay close to the oracle."""
    def scen(kernel=None):
        topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
        sim = PacketSim(topo, kernel=kernel)
        sim.add_flow(FlowSpec(0, 0, 12, 16e6, 0.0, "dctcp"))
        sim.add_flow(FlowSpec(1, 1, 12, 16e6, 0.0, "dctcp"))
        sim.add_flow(FlowSpec(2, 2, 12, 2e6, 1.2e-3, "dctcp"))  # lands mid-steady
        sim.run()
        assert sim.all_done()
        return sim

    base = scen()
    k = WormholeKernel(WormholeConfig())
    wh = scen(k)
    errs = fct_errors(base, wh)
    assert k.stats["skip_backs"] >= 1
    # per-flow error stays within the Theorem-3 bound for the partition's
    # (auto-)θ ≈ 0.145 here; the big flows are near-exact
    assert max(errs.values()) < 0.15
    assert sorted(errs.values())[1] < 0.02  # at most one small-flow outlier


def test_disjoint_partitions_do_not_interact():
    """Two flows on disjoint paths: parking one must not perturb the other
    (Definition 1 exclusivity)."""
    topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
    base = PacketSim(topo)
    base.add_flow(FlowSpec(0, 0, 1, 4e6, 0.0, "dctcp"))
    base.add_flow(FlowSpec(1, 4, 5, 4e6, 0.0, "dctcp"))
    base.run()
    k = WormholeKernel(WormholeConfig())
    wh = PacketSim(topo, kernel=k)
    wh.add_flow(FlowSpec(0, 0, 1, 4e6, 0.0, "dctcp"))
    wh.add_flow(FlowSpec(1, 4, 5, 4e6, 0.0, "dctcp"))
    wh.run()
    assert len(k.index.parts) <= 2 or True
    for fid in (0, 1):
        assert abs(wh.results[fid].fct - base.results[fid].fct) / base.results[fid].fct < 0.02


@pytest.mark.parametrize("cca", ["hpcc", "timely", "dcqcn"])
def test_other_ccas_bounded_error(cca):
    base = ring_workload(cca=cca, waves=1)
    k = WormholeKernel(WormholeConfig())
    wh = ring_workload(k, cca=cca, waves=1)
    errs = fct_errors(base, wh)
    assert sum(errs.values()) / len(errs) < 0.015, f"{cca}: {max(errs.values())}"


def test_worst_case_degrades_gracefully():
    """Random short flows (public-cloud-ish): Wormhole must not be *wrong*,
    even when there is little to skip (paper Limitations)."""
    import numpy as np
    rng = np.random.default_rng(3)

    def scen(kernel=None):
        topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
        sim = PacketSim(topo, kernel=kernel)
        for fid in range(24):
            src, dst = rng.integers(0, 16, size=2) if False else (int(fid % 16), int((fid * 7 + 3) % 16))
            if src == dst:
                dst = (dst + 1) % 16
            sim.add_flow(FlowSpec(fid, src, dst, float(2e5 + (fid % 5) * 1e5),
                                  fid * 3e-5, "dctcp"))
        sim.run()
        assert sim.all_done()
        return sim

    base = scen()
    wh = scen(WormholeKernel(WormholeConfig()))
    errs = fct_errors(base, wh)
    assert sum(errs.values()) / len(errs) < 0.03


def _forced_replay(cca: str):
    """Form a single-flow partition, hand it a synthetic memo hit, and run
    through the replay unpark — returns the flow for CCA-state inspection."""
    topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
    k = WormholeKernel(WormholeConfig())
    sim = PacketSim(topo, kernel=k)
    f = sim.add_flow(FlowSpec(0, 0, 12, 1e8, 0.0, cca))
    sim.run(until=2e-5)                    # started + partition formed (miss)
    part = next(iter(k.parts.values()))
    assert part.fcg is not None and not f.parked
    hit = MemoHit(
        entry=MemoEntry(fcg=part.fcg, end_rates=[5e9], sizes=[1e5],
                        t_conv=1e-4, end_reason=STEADY),
        mapping={0: 0})
    k._apply_hit(part, hit, sim.now)
    assert f.parked
    sim.run(until=sim.now + 2e-4)          # the replay horizon fires
    assert k.stats["replays"] == 1 and k.stats["unparks"] == 1
    return f


def test_replay_restores_window_for_window_ccas():
    f = _forced_replay("dctcp")
    # w IS the control variable: the stored FCG_end rate must be jumped to
    assert f.cca.r == pytest.approx(5e9)
    assert f.cca.w == pytest.approx(5e9 * max(f.cca.srtt, f.cca.base_rtt))


@pytest.mark.parametrize("cca", ["dcqcn", "timely"])
def test_replay_keeps_rate_cca_window_cap(cca):
    """Regression: for rate-based CCAs ``w`` is a loose in-flight cap, not
    the control variable — shrinking it to r*srtt after a replay pinned the
    flow at its parked rate (it could never ramp past the fast-forward
    state until the cap was rebuilt)."""
    f = _forced_replay(cca)
    assert f.cca.r == pytest.approx(5e9)
    cap = 1.5 * f.cca.line_rate * f.cca.base_rtt
    assert f.cca.w == pytest.approx(cap), \
        "rate-CCA window cap must survive the replay untouched"
    assert f.cca.w > 5e9 * f.cca.srtt


@pytest.mark.slow
def test_dcqcn_replay_fct_parity():
    """The three named regressions end-to-end: DCQCN through actual memo
    replays (wave 2 fast-forwards wave 1's transients) stays at FCT parity
    with the packet oracle."""
    base = ring_workload(cca="dcqcn", waves=2)
    k = WormholeKernel(WormholeConfig())
    wh = ring_workload(k, cca="dcqcn", waves=2)
    assert k.stats["replays"] > 0, "scenario must exercise the replay path"
    errs = fct_errors(base, wh)
    assert sum(errs.values()) / len(errs) < 0.015


def test_kernel_threads_mtu_into_lookup_tolerance(monkeypatch):
    """The completion-match guard must scale with the simulation MTU
    (atol=2*mtu), not assume ~1500B frames."""
    seen = []
    orig = SimDB.lookup

    def spy(self, fcg, remaining, atol=None):
        seen.append(atol)
        return orig(self, fcg, remaining, atol)

    monkeypatch.setattr(SimDB, "lookup", spy)
    topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
    sim = PacketSim(topo, kernel=WormholeKernel(WormholeConfig()), mtu=500.0)
    sim.add_flow(FlowSpec(0, 0, 12, 2e6, 0.0, "dctcp"))
    sim.run(until=1e-4)
    assert seen and all(a == pytest.approx(2 * 500.0) for a in seen)


def test_packet_pausing_preserves_shared_buffer_pressure():
    """Paper §6.2: a parked partition keeps occupying its share of the
    switch's shared buffer, so co-located ports see the same usable space
    as in the baseline (drop/ECN timing preserved)."""
    from repro.net.topology import leaf_spine_clos

    def scen(kernel=None):
        topo = leaf_spine_clos(16, leaf_down=8, n_spines=2)
        # small shared pool so the coupling actually binds
        sim = PacketSim(topo, kernel=kernel, shared_buffer=300_000.0,
                        buffer_bytes=260_000.0)
        # partition A: steady elephants into host 8 (will be parked)
        sim.add_flow(FlowSpec(0, 0, 8, 6e6, 0.0, "dctcp"))
        sim.add_flow(FlowSpec(1, 1, 8, 6e6, 0.0, "dctcp"))
        # partition B: bursty incast into host 9 via the same leaf switch
        for i in range(4):
            sim.add_flow(FlowSpec(10 + i, 2 + i, 9, 1.5e6, 3e-4 + i * 1e-5,
                                  "dctcp"))
        sim.run()
        assert sim.all_done()
        return sim

    base = scen()
    k = WormholeKernel(WormholeConfig())
    wh = scen(k)
    errs = [abs(wh.results[f].fct - r.fct) / r.fct
            for f, r in base.results.items()]
    assert sum(errs) / len(errs) < 0.03, errs
    # the elephants must actually have been parked for the test to bite
    assert k.stats["parks"] >= 1
