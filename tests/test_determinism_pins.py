"""Regression pins for the determinism properties the reprolint D-pass
enforces (ISSUE 8 satellite): stable_hash stays process-stable byte-for-byte,
and a full wormhole run is bit-identical across interpreters with different
PYTHONHASHSEED values — i.e. nothing in partition formation, parking, or
memo keying reads hash-salt-dependent ordering anymore.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.fcg import stable_hash
from repro.core.partition import PartitionIndex


def test_stable_hash_pinned_values():
    # pinned against blake2b(repr(obj), digest_size=6) & 0x7FFFFFFFFFFF —
    # any change to the scheme orphans every SimDB on disk, so it must be a
    # deliberate, version-bumped decision, never an accident
    assert stable_hash(()) == 3492114727459
    assert stable_hash((1, 2, 3)) == 137031301605602
    assert stable_hash(("dctcp", (4, 8))) == 2227764377384
    assert stable_hash(("a", ("b", ("c",)))) == 71742425096237


def test_stable_hash_fits_48_bits():
    for obj in [(), (0,), ("x", 1, ("y", 2)), tuple(range(100))]:
        h = stable_hash(obj)
        assert 0 <= h < 2**48


def test_partition_index_orders_are_value_determined():
    # add_flow/remove_flow iterate their merge/split sets sorted now: the
    # flow->pid and port->pid mapping insertion order must be a pure
    # function of the ids, whatever order the sets hashed in
    def build():
        idx = PartitionIndex()
        for fid, ports in [(3, {1, 2}), (1, {2, 3}), (2, {9}),
                           (7, {3, 4}), (5, {9, 10})]:
            idx.add_flow(fid, frozenset(ports))
        idx.remove_flow(1)   # splits the merged partition
        return idx
    a, b = build(), build()
    assert list(a.flow_pid.items()) == list(b.flow_pid.items())
    assert list(a.port_pid.items()) == list(b.port_pid.items())
    assert {pid: sorted(fl) for pid, fl in a.parts.items()} == \
           {pid: sorted(fl) for pid, fl in b.parts.items()}


_WORMHOLE_RUN = textwrap.dedent("""
    import json, sys
    from repro.core.memo import SimDB
    from repro.core.wormhole import WormholeConfig, WormholeKernel
    from repro.net.flows import FlowSpec
    from repro.net.packet_sim import PacketSim
    from repro.net.topology import rail_optimized_fat_tree

    topo = rail_optimized_fat_tree(8, gpus_per_server=4, leaf_radix=8,
                                   n_spines=2)
    kernel = WormholeKernel(WormholeConfig(), SimDB())
    sim = PacketSim(topo, kernel=kernel)
    fid = 0
    for w in range(2):
        for r in range(4):
            for s in range(8):
                sim.add_flow(FlowSpec(fid, s * 4 + r, ((s + 1) % 8) * 4 + r,
                                      2e6, w * 0.02, "dctcp"))
                fid += 1
    sim.run()
    out = {
        "fcts": {str(f): r.fct for f, r in sorted(sim.results.items())},
        "events": sim.events_processed,
        "hops": sim.packet_hop_events,
        "report": {k: v for k, v in sorted(kernel.report().items())
                   if isinstance(v, (int, float, str))},
    }
    json.dump(out, sys.stdout)
""")


@pytest.mark.slow
def test_wormhole_run_identical_across_hash_seeds():
    outs = []
    for seed in ("0", "31337"):
        env = dict(os.environ)
        env["PYTHONHASHSEED"] = seed
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (env.get("PYTHONPATH"), "src") if p)
        proc = subprocess.run([sys.executable, "-c", _WORMHOLE_RUN],
                              capture_output=True, text=True, env=env,
                              timeout=600)
        assert proc.returncode == 0, proc.stderr
        outs.append(json.loads(proc.stdout))
    assert outs[0] == outs[1]   # bit-identical fcts, counters, report
