"""Deterministic fallback for the `hypothesis` API surface these tests use.

When `hypothesis` is installed the test modules import it directly and this
file is unused.  Without it, `@given` degrades to a seeded loop over
deterministically drawn examples — less adversarial than real shrinking
property testing, but the properties still get exercised and the suite
collects cleanly with zero optional dependencies.

Only the strategies the repo's tests need are implemented:
integers / floats / lists / frozensets / dictionaries / randoms.
"""
from __future__ import annotations

import random
import sys

_MAX_EXAMPLES_CAP = 100   # keep the no-dependency fallback fast


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(r):
        return [elements.draw(r) for _ in range(r.randint(min_size, max_size))]
    return _Strategy(draw)


def frozensets(elements: _Strategy, min_size: int = 0,
               max_size: int = 10) -> _Strategy:
    def draw(r):
        target = r.randint(min_size, max_size)
        out: set = set()
        for _ in range(50 * max(target, 1)):
            if len(out) >= target:
                break
            out.add(elements.draw(r))
        return frozenset(out)
    return _Strategy(draw)


def dictionaries(keys: _Strategy, values: _Strategy, min_size: int = 0,
                 max_size: int = 10) -> _Strategy:
    def draw(r):
        target = r.randint(min_size, max_size)
        out: dict = {}
        for _ in range(50 * max(target, 1)):
            if len(out) >= target:
                break
            out[keys.draw(r)] = values.draw(r)
        return out
    return _Strategy(draw)


def randoms(use_true_random: bool = False) -> _Strategy:
    return _Strategy(lambda r: random.Random(r.getrandbits(48)))


def settings(max_examples: int = 50, deadline=None, **_ignored):
    def deco(fn):
        fn._hyp_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strategies: _Strategy):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the strategy parameters (it would resolve them as fixtures)
        def wrapper():
            n = getattr(fn, "_hyp_settings", {}).get("max_examples", 50)
            for i in range(min(n, _MAX_EXAMPLES_CAP)):
                r = random.Random(0xC0FFEE ^ (i * 0x9E3779B9))
                fn(*[s.draw(r) for s in strategies])
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


# `import hypothesis.strategies as st` fallback: the module doubles as `st`
st = sys.modules[__name__]
