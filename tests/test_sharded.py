"""Serial <-> sharded <-> parallel equivalence for the partition-sharded
event loop (repro.net.sharded_sim).

The load-bearing property: for ANY flow set, the sharded loop — with any
``intra_workers`` — produces FCTs (and event counts) *identical* to the
single-heap serial loop, because per-partition lanes preserve the serial
loop's intra-lane (t, seq) order and partitions share no ports (Definition
1).  Lane/port exclusivity is checked with the same invariants the
partition property tests use (PartitionIndex.check_invariants)."""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                    # optional dep: deterministic fallback
    from hypcompat import given, settings, st

from repro.api import FlowSpec, Scenario, TopologySpec, run
from repro.core.wormhole import WormholeConfig, WormholeKernel
from repro.net.packet_sim import PacketSim
from repro.net.sharded_sim import ShardedPacketSim
from repro.net.topology import leaf_spine_clos


def _results(sim):
    return {fid: r.fct for fid, r in sim.results.items()}


def _run_pair(flows, kernel_cfg=None, validate=True):
    """(serial PacketSim, sharded ShardedPacketSim) over the same flows."""
    def build(cls, **kw):
        topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
        kernel = WormholeKernel(kernel_cfg) if kernel_cfg is not None else None
        sim = cls(topo, kernel=kernel, **kw)
        for fl in flows:
            sim.add_flow(fl)
        sim.run()
        assert sim.all_done()
        return sim

    serial = build(PacketSim)
    sharded = build(ShardedPacketSim, validate=validate)
    return serial, sharded


def _random_flows(r, n):
    flows = []
    for fid in range(n):
        src = r.randrange(16)
        dst = r.randrange(16)
        if dst == src:
            dst = (dst + 1) % 16
        flows.append(FlowSpec(
            fid, src, dst, float(r.randrange(50_000, 600_000)),
            r.randrange(0, 20) * 1e-4,
            r.choice(["dctcp", "dcqcn", "timely", "hpcc"])))
    return flows


@given(st.randoms(use_true_random=False), st.integers(2, 14))
@settings(max_examples=10, deadline=None)
def test_sharded_serial_loop_is_exact_on_random_flows(r, n):
    """Property: lane-structured execution == single-heap execution,
    event-for-event, on arbitrary flow sets (packet backend)."""
    serial, sharded = _run_pair(_random_flows(r, n))
    assert _results(serial) == _results(sharded)
    assert serial.events_processed == sharded.events_processed


@given(st.randoms(use_true_random=False), st.integers(2, 10))
@settings(max_examples=6, deadline=None)
def test_sharded_exact_under_wormhole_kernel(r, n):
    """Property: the Wormhole kernel's partition lifecycle drives lane
    merge/split and the sharded run stays identical to serial."""
    serial, sharded = _run_pair(_random_flows(r, n), WormholeConfig())
    assert _results(serial) == _results(sharded)
    assert serial.events_processed == sharded.events_processed


def test_lane_port_exclusivity_invariants():
    """No lane ever holds a foreign flow's event and the index satisfies
    Definition 1 throughout (validate=True asserts per event; this test
    additionally checks the final state explicitly)."""
    flows = [FlowSpec(0, 0, 8, 4e6, 0.0, "dctcp"),
             FlowSpec(1, 0, 9, 4e6, 5e-5, "dctcp"),   # merges with 0 mid-run
             FlowSpec(2, 4, 5, 4e6, 0.0, "dctcp"),    # stays disjoint
             FlowSpec(3, 12, 13, 2e6, 5e-4, "hpcc")]
    topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
    sim = ShardedPacketSim(topo, validate=True)
    for fl in flows:
        sim.add_flow(fl)
    sim.run(until=2e-3)
    sim.check_invariants()
    assert sim.shard_stats["merges"] >= 1, "scenario must exercise a merge"
    sim.run()
    assert sim.all_done()
    sim.check_invariants()


def test_sharded_refuses_shared_buffer():
    topo = leaf_spine_clos(16, leaf_down=4, n_spines=2)
    with pytest.raises(ValueError, match="shared_buffer"):
        ShardedPacketSim(topo, shared_buffer=3e5)


def _api_scenario(seed: int) -> Scenario:
    import random
    r = random.Random(seed)
    flows = _random_flows(r, 10)
    return Scenario(f"sharded-eq-{seed}",
                    TopologySpec("clos", {"n_hosts": 16, "leaf_down": 4,
                                          "n_spines": 2}),
                    flows=flows)


@pytest.mark.parametrize("backend", ["packet", "wormhole"])
def test_intra_workers_identical_through_api(backend):
    """parallel='partitions' with intra_workers in {1, 2, 4} matches the
    serial loop exactly (FCTs and event counts) through repro.api."""
    scn = _api_scenario(7)
    serial = run(scn, backend=backend)
    for iw in (1, 2, 4):
        par = run(scn, backend=backend, parallel="partitions",
                  intra_workers=iw)
        assert par.fcts == serial.fcts, f"iw={iw} diverged"
        assert par.events_processed == serial.events_processed
        assert par.extras["shard"]["intra_workers"] == iw
        if iw > 1 and backend == "packet":
            # the equivalence must not be vacuous: the fan-out machinery
            # has to actually ship lanes to workers on this scenario
            assert par.extras["shard"]["dispatches"] > 0, \
                "parallel path never dispatched — test covers nothing"


def test_workload_driver_phases_identical_under_fanout():
    """A phase-DAG workload (driver launches = real-time flow-entry
    interrupts) stays exact under the parallel fan-out, including the
    window-shrink / serial-redo paths."""
    from repro.api import training_scenario
    scn = training_scenario(n_gpus=16, cca="dctcp", scale=1 / 4096,
                            name="sharded-wl16")
    serial = run(scn, backend="wormhole")
    par = run(scn, backend="wormhole", parallel="partitions", intra_workers=2)
    assert par.fcts == serial.fcts
    assert par.events_processed == serial.events_processed
    assert par.iteration_time == serial.iteration_time


def test_quickstart_identical_under_fanout():
    """Acceptance scenario: the quickstart example, wormhole backend,
    intra_workers=2 — FCTs identical to the serial run."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.quickstart import make_scenario
    scn = make_scenario()
    serial = run(scn, backend="wormhole")
    par = run(scn, backend="wormhole", parallel="partitions", intra_workers=2)
    assert par.fcts == serial.fcts
    assert par.events_processed == serial.events_processed
    assert par.extras["shard"]["dispatches"] > 0


@pytest.mark.slow
def test_64gpu_preset_identical_under_fanout():
    """Acceptance scenario: the 64-GPU Table-1 workload preset, wormhole
    backend, intra_workers=2 — FCTs identical to the serial run."""
    from repro.api import training_scenario
    scn = training_scenario(n_gpus=64, cca="hpcc", scale=1 / 256)
    serial = run(scn, backend="wormhole")
    par = run(scn, backend="wormhole", parallel="partitions", intra_workers=2)
    assert par.fcts == serial.fcts
    assert par.events_processed == serial.events_processed
    assert par.extras["shard"]["dispatches"] > 0
